"""Metric-generic solver substrate tests.

One upload, one scheduler, many graph analytics: closeness, k-hop
reachability and connected components ride the same planned, fused,
QoS-scheduled serving path as betweenness. Three layers of evidence:

* **parity** — every metric's exact sweep, through every registered
  backend (dense / COO / CSR adjacency), matches a plain-numpy
  reference (BFS/Dijkstra closeness, hop-limited BFS, union-find);
* **fusion** — cross-metric fused ``step_segmented`` ticks (betweenness
  and closeness rows sharing one collective) are *bitwise* equal to
  running each slot's rows alone, and a mixed-metric service run
  retires each request bit-identical to serving it by itself;
* **facade stability** — the default metric prices, plans and
  serializes exactly as before (no ``metric``/``hops`` keys in default
  plan JSON), while forward-only metrics are priced at one sweep
  against betweenness's two.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic sweep, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.bc import (BatchAssembler, BCQuery, ExecutionConfig, build_executor,
                      fuse_group, metric_spec, plan, registered_metrics,
                      scatter, solve)
from repro.core import cc_ref, closeness_ref, khop_ref
from repro.graphs.generators import rmat
from repro.serve.bc_service import BCRequest, BCService

_CACHE = {}

BACKENDS = ("dense", "coo", "csr")


def _graph():
    if "g" not in _CACHE:
        g = rmat(6, 8, seed=5)
        g, _ = g.remove_isolated()
        _CACHE["g"] = g
    return _CACHE["g"]


def _host_executor():
    if "host" not in _CACHE:
        g = _graph()
        _CACHE["host"] = build_executor(
            g, plan(g, BCQuery(mode="approx", n_b=64), n_devices=1))
    return _CACHE["host"]


# ------------------------------------------------------------- registry
def test_registry_and_fuse_groups():
    names = registered_metrics()
    assert {"betweenness", "closeness", "khop", "components"} <= set(names)
    bc = metric_spec("betweenness")
    assert bc.sweeps == 2 and bc.needs_backward and bc.sampled
    cl = metric_spec("closeness")
    assert cl.sweeps == 1 and not cl.needs_backward and cl.sampled
    kh = metric_spec("khop")
    assert kh.bounded and kh.sampled
    cc = metric_spec("components")
    assert cc.fixed_point and not cc.sampled
    with pytest.raises(ValueError, match="registered"):
        metric_spec("nope")
    # fusion compatibility: metrics sharing the unbounded forward sweep
    # share one group; hop bounds and fixed points do not
    assert fuse_group("betweenness") == fuse_group("closeness")
    assert fuse_group("khop", 2) == fuse_group("khop", 2)
    assert fuse_group("khop", 2) != fuse_group("khop", 3)
    assert fuse_group("khop", 2) != fuse_group("betweenness")
    assert fuse_group("components") != fuse_group("closeness")


def test_query_and_plan_metric_validation():
    with pytest.raises(ValueError, match="hops"):
        BCQuery(metric="khop")  # bounded metric needs a bound
    with pytest.raises(ValueError, match="hops"):
        BCQuery(metric="closeness", hops=3)  # unbounded takes none
    with pytest.raises(ValueError, match="fixed point"):
        BCQuery(mode="approx", metric="components")  # exact only


def test_default_plan_json_has_no_metric_keys():
    """Wire stability: a default-metric plan serializes byte-for-byte as
    before the metric field existed; non-default metrics record
    themselves."""
    g = _graph()
    d = plan(g, BCQuery(mode="approx"), n_devices=1).to_json()
    assert "metric" not in d and "hops" not in d
    d = plan(g, BCQuery(mode="approx", metric="closeness"),
             n_devices=1).to_json()
    assert d["metric"] == "closeness" and "hops" not in d
    d = plan(g, BCQuery(mode="approx", metric="khop", hops=3),
             n_devices=1).to_json()
    assert d["metric"] == "khop" and d["hops"] == 3


def test_forward_only_metrics_price_one_sweep():
    """The planner prices closeness (forward sweep only) at half the
    iteration volume of betweenness (forward + backward) for the same
    configuration, and records it in the plan."""
    g = _graph()
    pb = plan(g, BCQuery(mode="approx", n_b=32), n_devices=1)
    pc = plan(g, BCQuery(mode="approx", n_b=32, metric="closeness"),
              n_devices=1)
    # comm volume scales with spec.sweeps × est_iters × n_batches: the
    # forward-only metric pays exactly half the sweep volume
    assert pc.predicted_comm_bytes * 2 == pb.predicted_comm_bytes
    assert pc.predicted_seconds < pb.predicted_seconds


# ------------------------------------------------- parity vs references
@st.composite
def _rmat_cases(draw):
    scale = draw(st.integers(min_value=3, max_value=5))
    degree = draw(st.integers(min_value=2, max_value=8))
    seed = draw(st.integers(min_value=0, max_value=2 ** 31 - 1))
    metric = draw(st.sampled_from(
        ["closeness", "khop:1", "khop:2", "khop:3", "components"]))
    return scale, degree, seed, metric


@settings(max_examples=10, deadline=None)
@given(_rmat_cases())
def test_metric_parity_on_random_rmat_all_backends(case):
    """Every metric's exact sweep == its plain-numpy reference, through
    the dense, COO and frontier-CSR adjacency backends alike — the
    generic masked-(Tw, Tm) pipeline is backend-agnostic by
    construction, this pins it."""
    scale, degree, seed, metric = case
    g = rmat(scale, degree, seed=seed)
    name, _, hops = metric.partition(":")
    if name == "closeness":
        ref, exact = closeness_ref(g), False
    elif name == "khop":
        ref, exact = khop_ref(g, hops=int(hops or 0)), True
    else:
        ref, exact = cc_ref(g), True
    for backend in BACKENDS:
        q = BCQuery(mode="exact", metric=name, hops=int(hops or 0),
                    execution=ExecutionConfig(backend=backend))
        lam = solve(g, q, plan=plan(g, q, n_devices=1)).lam
        if exact:  # integer-valued counts/labels: exact in f32/f64
            np.testing.assert_array_equal(lam, ref, err_msg=backend)
        else:
            np.testing.assert_allclose(lam, ref, rtol=1e-4, atol=1e-5,
                                       err_msg=backend)


def test_components_labels_bitwise_union_find():
    """CC labels are the min vertex id per component — bitwise equal to
    union-find, on every backend."""
    g = _graph()
    ref = cc_ref(g)
    for backend in BACKENDS:
        q = BCQuery(mode="exact", metric="components",
                    execution=ExecutionConfig(backend=backend))
        res = solve(g, q, plan=plan(g, q, n_devices=1))
        np.testing.assert_array_equal(res.lam, ref, err_msg=backend)
        assert res.converged and res.n_swept == g.n


def test_approx_closeness_converges_to_reference():
    """Closeness through the adaptive sampling driver: the estimator's
    n-scaled mean converges onto the exact farness profile."""
    g = _graph()
    res = solve(g, BCQuery(mode="approx", metric="closeness", eps=0.02,
                           delta=0.1, seed=7))
    assert res.approx is not None and res.converged
    ref = closeness_ref(g)
    # λ̂ estimates Σ_s d(s, v); top of the farness order must agree
    assert set(res.topk(3)) <= set(np.argsort(ref)[::-1][:8])


# --------------------------------------------------- cross-metric fusion
def test_single_metric_segmented_matches_legacy_dispatch():
    """``metrics=('betweenness', ...)`` (all default) must route through
    the exact same compiled step as the legacy no-metrics call —
    bitwise, not just close."""
    ex = _host_executor()
    n = _graph().n
    rng = np.random.default_rng(3)
    src = rng.integers(0, n, 24).astype(np.int32)
    sid = np.sort(rng.integers(0, 3, 24).astype(np.int32))
    valid = np.ones(24, bool)
    legacy = ex.step_segmented(src, valid, sid, 3)
    tagged = ex.step_segmented(src, valid, sid, 3,
                               metrics=("betweenness",) * 3)
    for a, b in zip(legacy, tagged):
        np.testing.assert_array_equal(a, b)


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["betweenness", "closeness"]),
                          st.integers(min_value=1, max_value=40)),
                min_size=1, max_size=5),
       st.integers(min_value=0, max_value=2 ** 16))
def test_cross_metric_fused_bitwise_equals_sequential(slots, seed):
    """The tentpole guarantee: a fused tick mixing betweenness and
    closeness rows in one ``step_segmented`` collective returns, for
    every slot, statistics bitwise-identical to running that slot's
    rows alone under its own metric."""
    ex = _host_executor()
    n = _graph().n
    rng = np.random.default_rng(seed)
    demand = [(j, rng.integers(0, n, ln).astype(np.int32))
              for j, (_, ln) in enumerate(slots)]
    metric_of = {j: m for j, (m, _) in enumerate(slots)}
    for fb in BatchAssembler(ex).assemble(demand):
        metrics = tuple(metric_of[key] for key in fb.slots)
        s1, s2, nr = ex.step_segmented(fb.sources, fb.valid, fb.slot_ids,
                                       fb.n_slots, metrics=metrics)
        for j, key in enumerate(fb.slots):
            rows = fb.sources[(fb.slot_ids == j) & fb.valid]
            b1, b2, bn = ex.step_segmented(
                rows, np.ones(rows.shape[0], bool),
                np.zeros(rows.shape[0], np.int32), 1,
                metrics=(metric_of[key],))
            np.testing.assert_array_equal(s1[j], b1[0])
            np.testing.assert_array_equal(s2[j], b2[0])
            np.testing.assert_array_equal(nr[j], bn[0])


def test_khop_fused_group_bitwise():
    """Hop-bounded slots fuse with matching bounds: two khop(2) slots
    share one bounded sweep, bitwise equal to solo runs."""
    ex = _host_executor()
    n = _graph().n
    rng = np.random.default_rng(11)
    demand = [(0, rng.integers(0, n, 9).astype(np.int32)),
              (1, rng.integers(0, n, 13).astype(np.int32))]
    for fb in BatchAssembler(ex).assemble(demand):
        s1, s2, nr = ex.step_segmented(fb.sources, fb.valid, fb.slot_ids,
                                       fb.n_slots,
                                       metrics=("khop",) * fb.n_slots,
                                       hops=2)
        for j, key in enumerate(fb.slots):
            rows = fb.sources[(fb.slot_ids == j) & fb.valid]
            b1, _, _ = ex.step_segmented(
                rows, np.ones(rows.shape[0], bool),
                np.zeros(rows.shape[0], np.int32), 1,
                metrics=("khop",), hops=2)
            np.testing.assert_array_equal(s1[j], b1[0])


# ------------------------------------------------------- service parity
def _serve(reqs, **kw):
    svc = BCService({"web": _graph()}, n_slots=4, **kw)
    for r in reqs:
        svc.submit(r)
    out = {r.rid: r for r in svc.run()}
    assert not svc.exhausted
    return out


def test_service_mixed_metrics_equal_isolated_runs():
    """A mixed-metric service run (betweenness + closeness fused into
    shared ticks, khop in its own group) retires every request with the
    same answer as a service run holding only that request — same
    (seed, rid) stream, same epoch schedule, same statistics.

    Closeness and khop compare *bitwise*: alone or fused they run the
    same metric-generic compiled step, and the segment sums accumulate
    each slot's rows in the same order. Betweenness alone dispatches the
    legacy byte-stable step (the pre-metric compiled program), while
    fused next to closeness it runs the generic one — two XLA programs
    whose f32 reduction orders may differ by an ulp, so it compares to
    float tolerance (the tick-level bitwise guarantee is
    ``test_cross_metric_fused_bitwise_equals_sequential``)."""
    reqs = [
        BCRequest(rid=0, graph="web", eps=0.1, delta=0.1, seed=3),
        BCRequest(rid=1, graph="web", eps=0.1, delta=0.1, seed=3,
                  metric="closeness"),
        BCRequest(rid=2, graph="web", eps=0.1, delta=0.1, seed=3,
                  metric="khop", hops=2),
    ]
    together = _serve(reqs)
    assert len(together) == 3
    for req in reqs:
        alone = _serve([req])[req.rid]
        mixed = together[req.rid]
        assert mixed.n_samples == alone.n_samples
        assert mixed.n_epochs == alone.n_epochs
        assert mixed.converged == alone.converged
        if req.metric == "betweenness":
            assert mixed.topk == alone.topk
            np.testing.assert_allclose(mixed.lam, alone.lam, rtol=1e-5)
            np.testing.assert_allclose(mixed.halfwidth, alone.halfwidth,
                                       rtol=1e-4, atol=1e-9)
        else:
            assert mixed.topk == alone.topk
            np.testing.assert_array_equal(mixed.lam, alone.lam)
            np.testing.assert_array_equal(mixed.halfwidth, alone.halfwidth)


def test_service_components_answers_immediately():
    """Fixed-point requests are answered at admission without occupying
    a slot, even when every slot is busy."""
    svc = BCService({"web": _graph()}, n_slots=1)
    svc.submit(BCRequest(rid=0, graph="web", eps=0.02, delta=0.1))
    svc.step()  # rid 0 occupies the only slot
    assert svc.active == 1
    svc.submit(BCRequest(rid=1, graph="web", metric="components"))
    svc.step()
    done = {r.rid for r in svc.finished}
    assert 1 in done  # answered while the slot was still busy
    cc = next(r for r in svc.finished if r.rid == 1)
    ref = cc_ref(_graph())
    ids = np.argsort(ref)[::-1][:10]
    np.testing.assert_array_equal(cc.lam, ref[ids])
    assert cc.converged and np.all(cc.halfwidth == 0.0)
    svc.run()  # drain rid 0 cleanly


def test_service_plan_records_metric():
    """Each non-default request's per-request plan carries its metric —
    the bench's per-metric plan evidence."""
    out = _serve([BCRequest(rid=0, graph="web", eps=0.1, delta=0.1,
                            metric="closeness")])
    d = out[0].plan.to_json()
    assert d["metric"] == "closeness"
