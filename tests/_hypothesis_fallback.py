"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The property tests import ``given``/``settings``/``strategies`` from here
as a fallback, so the suite collects and still exercises the properties on
a fixed pseudo-random sweep (seeded per test name — stable across runs, no
shrinking, no example database). With real hypothesis installed (see
``requirements-dev.txt``) the fallback is never imported.
"""
from __future__ import annotations

import functools
import types
import zlib

import numpy as np


class _Strategy:
    """A strategy is just a draw function rng -> value."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def just(value):
    return _Strategy(lambda rng: value)


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))])


def one_of(*strats):
    return _Strategy(
        lambda rng: strats[int(rng.integers(0, len(strats)))]._draw(rng))


def tuples(*strats):
    return _Strategy(lambda rng: tuple(s._draw(rng) for s in strats))


def lists(elements, *, min_size=0, max_size=10):
    return _Strategy(lambda rng: [
        elements._draw(rng)
        for _ in range(int(rng.integers(min_size, max_size + 1)))])


def composite(f):
    @functools.wraps(f)
    def builder(*args, **kwargs):
        return _Strategy(
            lambda rng: f(lambda s: s._draw(rng), *args, **kwargs))

    return builder


def given(*strats):
    def deco(f):
        def wrapper():
            n = getattr(wrapper, "_max_examples", 20)
            rng = np.random.default_rng(zlib.crc32(f.__name__.encode()))
            for _ in range(n):
                f(*(s._draw(rng) for s in strats))

        # No functools.wraps: __wrapped__ would make pytest unwrap to f and
        # demand fixtures for the strategy-filled parameters. The zero-arg
        # __signature__ is what pytest must see.
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        # Pytest plugins (e.g. anyio) probe fn.hypothesis.inner_test —
        # mirror real hypothesis's attribute shape.
        wrapper.hypothesis = types.SimpleNamespace(inner_test=f)
        return wrapper

    return deco


def settings(max_examples=20, deadline=None, **_ignored):
    def deco(f):
        f._max_examples = max_examples
        return f

    return deco


class _StrategiesNamespace:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    just = staticmethod(just)
    sampled_from = staticmethod(sampled_from)
    one_of = staticmethod(one_of)
    tuples = staticmethod(tuples)
    lists = staticmethod(lists)
    composite = staticmethod(composite)


strategies = _StrategiesNamespace()
