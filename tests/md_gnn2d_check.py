"""2D edge-partitioned GCN == reference GCN (8 devices, subprocess)."""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.graphs.generators import erdos_renyi
from repro.models import gnn as G
from repro.models.gnn_dist import (Grid2D, abstract_inputs, bucket_edges,
                                   build_gcn2d_loss, layout_features,
                                   make_grid)


def main():
    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    n, d_in, dh, classes = 37, 12, 16, 5
    g = erdos_renyi(n, 0.15, seed=2)
    x = rng.normal(size=(n, d_in)).astype(np.float32)
    labels = rng.integers(0, classes, n).astype(np.int32)
    deg = np.bincount(g.dst, minlength=n).astype(np.float32)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    coef = (dinv[g.src] * dinv[g.dst]).astype(np.float32)

    # --- reference: plain segment-sum GCN (message part only, no self loop)
    params = {"w": [jnp.asarray(rng.normal(size=(d_in, dh)).astype(np.float32)
                                / np.sqrt(d_in)),
                    jnp.asarray(rng.normal(size=(dh, classes)).astype(np.float32)
                                / np.sqrt(dh))]}

    def ref_loss(params):
        h = jnp.asarray(x)
        for i, w in enumerate(params["w"]):
            hw = h @ w
            m = hw[jnp.asarray(g.src)] * jnp.asarray(coef)[:, None]
            h = jax.ops.segment_sum(m, jnp.asarray(g.dst), num_segments=n)
            if i == 0:
                h = jax.nn.relu(h)
        logz = jax.nn.logsumexp(h, axis=-1)
        gold = jnp.take_along_axis(h, jnp.asarray(labels)[:, None], 1)[:, 0]
        return jnp.mean(logz - gold)

    # --- 2D-partitioned version
    grid = make_grid(mesh, n, g.nnz)
    src_b, dst_b, coef_b = bucket_edges(grid, g.src, g.dst, coef)
    xp = layout_features(grid, x)
    lp = layout_features(grid, labels[:, None].astype(np.float32))[:, 0]
    mask = layout_features(grid, np.ones((n, 1), np.float32))[:, 0] > 0

    loss2d = build_gcn2d_loss(mesh, grid, n_layers=2)
    with compat.set_mesh(mesh):
        args = (params, jnp.asarray(xp), jnp.asarray(src_b),
                jnp.asarray(dst_b), jnp.asarray(coef_b),
                jnp.asarray(lp.astype(np.int32)), jnp.asarray(mask))
        l2d = jax.jit(loss2d)(*args)
        g2d = jax.jit(jax.grad(loss2d))(*args)

    lref = ref_loss(params)
    gref = jax.grad(ref_loss)(params)
    print("ref loss", float(lref), "2d loss", float(l2d))
    np.testing.assert_allclose(float(lref), float(l2d), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(gref), jax.tree.leaves(g2d)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)
    print("2D-partitioned GCN == reference (loss + grads)")
    print("ALL-OK")


if __name__ == "__main__":
    main()
