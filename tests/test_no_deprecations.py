"""The repo must not trip its own deprecation shims (ISSUE 7 gate).

PR 6 deprecated the stringly ``backend=`` / ``use_kernel=`` kwargs in
favour of the typed ``ExecutionConfig``; the fast lane runs with
``filterwarnings = error::DeprecationWarning:repro…`` (pytest.ini) so any
repro module calling a deprecated API fails loudly. This test drives the
blessed modern surfaces end to end under ``error`` to pin that the paved
road itself is warning-free — including the benchmark drivers, which run
outside pytest and would otherwise drift silently.
"""
import importlib
import warnings

import numpy as np
import pytest


def test_modern_surfaces_are_deprecation_free():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        from repro.bc import BCQuery, ExecutionConfig, plan, solve
        from repro.core import mfbc
        from repro.graphs.generators import rmat

        g = rmat(6, 8, seed=3).dedup()
        mfbc(g, n_b=8, execution=ExecutionConfig(backend="coo"))
        q = BCQuery(mode="approx", strategy="uniform", max_samples=8,
                    seed=0, execution=ExecutionConfig(backend="coo"))
        assert plan(g, q, n_devices=1).to_json()["backend"] == "coo"
        res = solve(g, q)
        assert np.all(np.asarray(res.lam) >= -1e-9)


def test_benchmark_drivers_import_deprecation_free():
    """The benchmark entry points (run outside pytest) stay on the paved
    road: importing them must not execute any deprecated call."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        for mod in ("benchmarks.bc_scaling", "tools.check_bench"):
            try:
                importlib.import_module(mod)
            except ImportError as e:  # repo-root not on sys.path
                pytest.skip(f"cannot import {mod} from here: {e}")


def test_legacy_kwargs_still_warn():
    """The shims themselves must keep warning (not silently dropped)."""
    from repro.bc import BCQuery

    with pytest.warns(DeprecationWarning, match="ExecutionConfig"):
        BCQuery(mode="approx", max_samples=8, backend="coo")
