"""Correctness of the core MFBC algorithms vs the numpy Brandes oracle.

Covers paper Lemma 4.1 (MFBF distances + multiplicities), Lemma 4.2 (MFBr
partial centrality factors), and Theorem 4.3 (full λ), on directed and
undirected, weighted and unweighted graphs, in both the dense and the COO
relaxation regimes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (brandes_bc, bfs_bc, coo_adj_from_graph,
                        dense_adj_from_graph, mfbc, mfbf, mfbr)
from repro.core.mfbc import mfbc_batch
from repro.graphs.generators import (erdos_renyi, path_graph, ring_of_cliques,
                                     rmat, uniform_random)


def _adj(g, backend):
    return dense_adj_from_graph(g) if backend == "dense" else coo_adj_from_graph(g)


GRAPHS = {
    "path8": lambda: path_graph(8),
    "path8_w": lambda: path_graph(8, weighted=True, seed=3),
    "roc4x4": lambda: ring_of_cliques(4, 4),
    "roc3x5_w": lambda: ring_of_cliques(3, 5, weighted=True, seed=1),
    "er40": lambda: erdos_renyi(40, 0.15, seed=7),
    "er40_w": lambda: erdos_renyi(40, 0.15, seed=7, weighted=True, max_weight=9),
    "er40_dir_w": lambda: erdos_renyi(40, 0.12, seed=11, weighted=True,
                                      max_weight=7, directed=True),
    "rmat5": lambda: rmat(5, 4, seed=5),
    "rmat5_dir_w": lambda: rmat(5, 3, seed=9, weighted=True, max_weight=5,
                                directed=True),
    "uni60": lambda: uniform_random(60, 6.0, seed=13),
}


@pytest.mark.parametrize("backend", ["dense", "coo"])
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_mfbf_matches_dijkstra(gname, backend):
    """Lemma 4.1: T(s, v) = (τ(s, v), σ̄(s, v))."""
    g = GRAPHS[gname]()
    sources = np.arange(min(g.n, 16), dtype=np.int32)
    _, dist_ref, sigma_ref = brandes_bc(g, sources=sources, return_aux=True)
    adj = _adj(g, backend)
    Tw, Tm = jax.jit(lambda a, s: mfbf(a, s))(adj, jnp.asarray(sources))
    Tw, Tm = np.asarray(Tw).copy(), np.asarray(Tm).copy()
    # The (s, s) entry differs by convention: the oracle says dist 0, MFBF
    # computes the shortest closed walk (masked to inf inside mfbc_batch
    # before MFBr — betweenness excludes t = s). Skip the diagonal.
    rows = np.arange(len(sources))
    for arr in (Tw, dist_ref):
        arr[rows, sources] = np.inf
    for arr in (Tm, sigma_ref):
        arr[rows, sources] = 0.0
    np.testing.assert_allclose(Tw, dist_ref, rtol=0, atol=0)
    np.testing.assert_allclose(Tm, sigma_ref, rtol=1e-6)


@pytest.mark.parametrize("backend", ["dense", "coo"])
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_mfbc_matches_brandes(gname, backend):
    """Theorem 4.3: λ(v) = Σ_{s,t} σ(s,t,v)/σ̄(s,t)."""
    g = GRAPHS[gname]()
    lam_ref = brandes_bc(g)
    lam = mfbc(g, n_b=8, backend=backend)
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("gname", ["path8", "roc4x4", "er40", "rmat5", "uni60"])
def test_bfs_baseline_matches_brandes(gname):
    """The CombBLAS-like BFS baseline agrees on unweighted graphs."""
    g = GRAPHS[gname]()
    lam_ref = brandes_bc(g)
    lam = bfs_bc(g, n_b=8, max_depth=g.n)
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-5, atol=1e-8)


def test_mfbc_fori_iterate_matches_while():
    g = GRAPHS["er40_w"]()
    lam_w = mfbc(g, n_b=8, iterate="while")
    lam_f = mfbc(g, n_b=8, iterate="fori", max_iters=g.n)
    np.testing.assert_allclose(lam_w, lam_f, rtol=1e-6)


def test_mfbc_batch_sizes_equivalent():
    """n_b is a performance knob only (paper: time/storage tradeoff)."""
    g = GRAPHS["er40"]()
    lam1 = mfbc(g, n_b=5)
    lam2 = mfbc(g, n_b=40)
    np.testing.assert_allclose(lam1, lam2, rtol=1e-6)


def test_path_graph_analytic():
    """On a path 0-1-...-7, interior vertex k has λ = 2·k·(n-1-k)."""
    n = 8
    g = path_graph(n)
    lam = mfbc(g, n_b=4)
    expect = np.array([2.0 * k * (n - 1 - k) for k in range(n)])
    np.testing.assert_allclose(lam, expect, rtol=1e-6)


def test_weighted_changes_centrality():
    """Weights must actually matter (the paper's weighted contribution)."""
    g_u = ring_of_cliques(3, 4)
    g_w = ring_of_cliques(3, 4, weighted=True, seed=2)
    lam_u = mfbc(g_u, n_b=6)
    lam_w = mfbc(g_w, n_b=6)
    assert not np.allclose(lam_u, lam_w)
    np.testing.assert_allclose(lam_w, brandes_bc(g_w), rtol=1e-5, atol=1e-8)


def test_disconnected_graph():
    """Unreachable pairs contribute nothing (and nothing NaNs out)."""
    import repro.graphs.formats as F
    src = np.array([0, 1, 3, 4], np.int32)
    dst = np.array([1, 0, 4, 3], np.int32)
    w = np.ones(4, np.float32)
    g = F.Graph(6, src, dst, w, directed=False)
    lam = mfbc(g, n_b=3)
    lam_ref = brandes_bc(g)
    assert np.all(np.isfinite(lam))
    np.testing.assert_allclose(lam, lam_ref, atol=1e-8)


def test_source_subset_approximation():
    g = GRAPHS["er40"]()
    srcs = np.array([0, 3, 7, 21], np.int32)
    lam = mfbc(g, n_b=4, sources=srcs)
    lam_ref = brandes_bc(g, sources=srcs)
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-5, atol=1e-8)
